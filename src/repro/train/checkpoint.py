"""Mesh-agnostic checkpointing with atomic rename + keep-k + resume.

Checkpoints store full (unsharded) tensors keyed by pytree path, so a job can
restart on a different device count / mesh shape — the elasticity story: the
restore path simply device_puts onto whatever shardings the new mesh derives.
Writes go to ``<dir>/tmp.<step>`` then ``os.replace`` to ``step_<N>`` so a
crash mid-save never corrupts the latest checkpoint.
"""
from __future__ import annotations

import json
import os
import re
import shutil

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(template, flat: dict[str, np.ndarray]):
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for path, leaf in leaves_with_path:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        if key not in flat:
            raise KeyError(f"checkpoint missing tensor {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs model {leaf.shape}"
            )
        new_leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def save_checkpoint(
    ckpt_dir: str,
    step: int,
    params,
    opt_state=None,
    data_state: dict | None = None,
    *,
    extra_meta: dict | None = None,
    keep: int = 3,
) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    np.savez(os.path.join(tmp, "params.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(tmp, "opt_state.npz"), **_flatten(opt_state))
    meta = {"step": step, "data_state": data_state or {}}
    meta.update(extra_meta or {})
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = list_checkpoints(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def list_checkpoints(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    steps = list_checkpoints(ckpt_dir)
    return steps[-1] if steps else None


def load_checkpoint(
    ckpt_dir: str,
    params_template,
    opt_template=None,
    *,
    step: int | None = None,
):
    """→ (step, params, opt_state, meta).  Templates supply structure/dtypes
    (e.g. from jax.eval_shape) — tensors come back as host numpy, ready for
    device_put under any mesh."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    params = _unflatten_into(
        params_template, dict(np.load(os.path.join(path, "params.npz")))
    )
    opt_state = None
    if opt_template is not None and os.path.exists(os.path.join(path, "opt_state.npz")):
        opt_state = _unflatten_into(
            opt_template, dict(np.load(os.path.join(path, "opt_state.npz")))
        )
    return step, params, opt_state, meta
