"""Mesh-agnostic checkpointing with verified integrity + keep-k + resume.

Checkpoints store full (unsharded) tensors keyed by pytree path, so a job can
restart on a different device count / mesh shape — the elasticity story: the
restore path simply device_puts onto whatever shardings the new mesh derives.

Durability and integrity (DESIGN.md §Training robustness):

* **Atomic, durable publish** — writes go to ``<dir>/tmp.<step>``, every
  file is fsync'd (the ``tune/cache.py`` idiom), then ``os.replace`` to the
  final name and an fsync of the parent directory.  A crash mid-save never
  tears the *latest* checkpoint.
* **Per-array checksum manifest** — ``manifest.json`` records a sha256 over
  (dtype, shape, bytes) of every saved array.  :func:`verify_checkpoint`
  re-hashes on load, so bit rot, a lying fsync, or a partially flushed
  ``.npz`` is *detected* instead of silently resuming garbage.
* **Verified fallback** — :func:`load_checkpoint` walks checkpoints newest →
  oldest and resumes from the newest one that verifies; the number of
  torn/corrupt checkpoints it skipped is reported in
  ``meta["_fallback_skipped"]`` so callers can count the event.
* **GC never orphans the last verified checkpoint** — keep-k trims old
  directories but always protects the newest checkpoint that passes
  verification, even when every newer one is torn.
* **Tag-suffixed names** — emergency / halt saves publish as
  ``step_<N>-<tag>`` so they can never clobber a good periodic checkpoint
  written at the same step; at equal step the untagged (periodic/final)
  checkpoint is preferred on resume.

Fault injection: ``save_checkpoint(..., faults=...)`` consults the shared
``ckpt_torn_write`` point (``uid`` = the step) once per save and, when it
fires, truncates ``params.npz`` *before* publishing — the checkpoint lands
on disk looking complete but fails verification, which is exactly the
failure the manifest exists to catch.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil

import jax
import numpy as np

from repro.faults import NULL_INJECTOR

MANIFEST_NAME = "manifest.json"

_NAME_RE = re.compile(r"step_(\d+)(?:-([A-Za-z0-9_.\-]+))?")


class CheckpointCorrupt(RuntimeError):
    """A checkpoint failed integrity verification (missing files, torn
    archive bytes, or a per-array checksum mismatch)."""


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(template, flat: dict[str, np.ndarray]):
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for path, leaf in leaves_with_path:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        if key not in flat:
            raise KeyError(f"checkpoint missing tensor {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs model {leaf.shape}"
            )
        new_leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def _array_digest(arr: np.ndarray) -> str:
    """sha256 over (dtype, shape, bytes) — a reshape or dtype flip with the
    same byte stream must not verify."""
    h = hashlib.sha256()
    h.update(str(arr.dtype).encode())
    h.update(str(tuple(arr.shape)).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def _fsync_file(path: str) -> None:
    with open(path, "rb") as f:
        os.fsync(f.fileno())


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - non-POSIX
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def checkpoint_name(step: int, tag: str = "") -> str:
    if tag and not re.fullmatch(r"[A-Za-z0-9_.\-]+", tag):
        raise ValueError(f"checkpoint tag {tag!r} must be filename-safe")
    return f"step_{step:08d}" + (f"-{tag}" if tag else "")


def step_of(name: str) -> int:
    m = _NAME_RE.fullmatch(name)
    if not m:
        raise ValueError(f"not a checkpoint name: {name!r}")
    return int(m.group(1))


def save_checkpoint(
    ckpt_dir: str,
    step: int,
    params,
    opt_state=None,
    data_state: dict | None = None,
    *,
    extra_meta: dict | None = None,
    keep: int = 3,
    tag: str = "",
    faults=None,
) -> str:
    faults = faults or NULL_INJECTOR
    os.makedirs(ckpt_dir, exist_ok=True)
    name = checkpoint_name(step, tag)
    tmp = os.path.join(ckpt_dir, f"tmp.{name}")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    manifest: dict = {"format": 1, "arrays": {}}
    flat_p = _flatten(params)
    np.savez(os.path.join(tmp, "params.npz"), **flat_p)
    manifest["arrays"]["params.npz"] = {
        k: _array_digest(v) for k, v in flat_p.items()
    }
    if opt_state is not None:
        flat_o = _flatten(opt_state)
        np.savez(os.path.join(tmp, "opt_state.npz"), **flat_o)
        manifest["arrays"]["opt_state.npz"] = {
            k: _array_digest(v) for k, v in flat_o.items()
        }
    meta = {"step": step, "data_state": data_state or {}, "tag": tag}
    meta.update(extra_meta or {})
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    with open(os.path.join(tmp, MANIFEST_NAME), "w") as f:
        json.dump(manifest, f)

    # Durability before publish: fsync every payload file, then the tmp dir
    # itself, so the rename below can never expose half-flushed contents.
    for fname in os.listdir(tmp):
        _fsync_file(os.path.join(tmp, fname))
    _fsync_dir(tmp)

    if faults.fires("ckpt_torn_write", uid=step) is not None:
        # Injected torn write: the checkpoint publishes with truncated array
        # bytes — complete-looking on disk, caught only by verification.
        ppath = os.path.join(tmp, "params.npz")
        size = os.path.getsize(ppath)
        with open(ppath, "r+b") as f:
            f.truncate(max(size // 2, 1))

    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    _fsync_dir(ckpt_dir)
    _gc(ckpt_dir, keep)
    return final


def list_checkpoint_names(ckpt_dir: str) -> list[str]:
    """All checkpoint directory names, sorted so the LAST entry is the
    preferred resume candidate: ascending by step, and at equal step the
    untagged (periodic/final) checkpoint sorts after tagged (emergency)
    ones."""
    if not os.path.isdir(ckpt_dir):
        return []
    names = []
    for name in os.listdir(ckpt_dir):
        m = _NAME_RE.fullmatch(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
            names.append(name)
    return sorted(names, key=lambda n: (step_of(n), _NAME_RE.fullmatch(n).group(2) is None))


def list_checkpoints(ckpt_dir: str) -> list[int]:
    """Distinct checkpoint steps, ascending (tag-agnostic)."""
    return sorted({step_of(n) for n in list_checkpoint_names(ckpt_dir)})


def latest_step(ckpt_dir: str) -> int | None:
    steps = list_checkpoints(ckpt_dir)
    return steps[-1] if steps else None


def verify_checkpoint(path: str) -> list[str]:
    """Integrity problems of one checkpoint directory ([] = verified).

    Checks: meta.json parses, the manifest exists and parses, every file it
    names loads, and every array matches its recorded sha256.  A checkpoint
    written before the manifest format (or with any torn/rotted bytes) does
    NOT verify.
    """
    problems: list[str] = []
    try:
        with open(os.path.join(path, "meta.json")) as f:
            json.load(f)
    except (OSError, ValueError) as e:
        return [f"meta.json unreadable: {e}"]
    try:
        with open(os.path.join(path, MANIFEST_NAME)) as f:
            manifest = json.load(f)
        arrays = manifest["arrays"]
    except (OSError, ValueError, KeyError, TypeError) as e:
        return [f"{MANIFEST_NAME} unreadable: {e}"]
    for fname, digests in arrays.items():
        fpath = os.path.join(path, fname)
        try:
            with np.load(fpath) as npz:
                keys = set(npz.files)
                missing = set(digests) - keys
                if missing:
                    problems.append(f"{fname}: missing arrays {sorted(missing)}")
                for key in sorted(set(digests) & keys):
                    if _array_digest(npz[key]) != digests[key]:
                        problems.append(f"{fname}: checksum mismatch for {key!r}")
        except Exception as e:  # noqa: BLE001 - torn zip bytes raise zoo-wide
            problems.append(f"{fname}: unreadable ({e!r})")
    return problems


def is_verified(path: str) -> bool:
    return not verify_checkpoint(path)


def latest_verified_name(ckpt_dir: str) -> str | None:
    """Newest checkpoint directory that passes verification (None if every
    checkpoint — or the directory itself — is missing/corrupt)."""
    for name in reversed(list_checkpoint_names(ckpt_dir)):
        if is_verified(os.path.join(ckpt_dir, name)):
            return name
    return None


def _gc(ckpt_dir: str, keep: int) -> None:
    """Keep-k trim that can never delete the last verified checkpoint.

    Verification runs newest-first and stops at the first verified name, so
    the common all-healthy case hashes exactly one checkpoint.
    """
    names = list_checkpoint_names(ckpt_dir)
    keep_names = set(names[-keep:]) if keep > 0 else set()
    protected = None
    for name in reversed(names):
        if is_verified(os.path.join(ckpt_dir, name)):
            protected = name
            break
    if protected is not None:
        keep_names.add(protected)
    for name in names:
        if name not in keep_names:
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)


def _load_dir(path: str, params_template, opt_template):
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    params = _unflatten_into(
        params_template, dict(np.load(os.path.join(path, "params.npz")))
    )
    opt_state = None
    if opt_template is not None and os.path.exists(
        os.path.join(path, "opt_state.npz")
    ):
        opt_state = _unflatten_into(
            opt_template, dict(np.load(os.path.join(path, "opt_state.npz")))
        )
    return params, opt_state, meta


def load_checkpoint(
    ckpt_dir: str,
    params_template,
    opt_template=None,
    *,
    step: int | None = None,
    verify: bool = True,
):
    """→ (step, params, opt_state, meta).  Templates supply structure/dtypes
    (e.g. from jax.eval_shape) — tensors come back as host numpy, ready for
    device_put under any mesh.

    With ``step=None`` (resume), checkpoints are tried newest → oldest and
    the newest *verified* one wins; the skipped-corrupt count is reported as
    ``meta["_fallback_skipped"]`` and the loaded directory name as
    ``meta["_name"]``.  With an explicit ``step``, only that step is tried
    (untagged preferred over tagged) and a corrupt checkpoint raises
    :class:`CheckpointCorrupt`.  ``verify=False`` restores the legacy
    trust-the-bytes behaviour (and is the only way to load a pre-manifest
    checkpoint).
    """
    names = list_checkpoint_names(ckpt_dir)
    if step is not None:
        names = [n for n in names if step_of(n) == step]
    if not names:
        raise FileNotFoundError(
            f"no checkpoints in {ckpt_dir}"
            + (f" at step {step}" if step is not None else "")
        )
    skipped = 0
    last_problems: list[str] = []
    for name in reversed(names):
        path = os.path.join(ckpt_dir, name)
        if verify:
            problems = verify_checkpoint(path)
            if problems:
                if step is not None:
                    raise CheckpointCorrupt(f"{path}: {problems}")
                skipped += 1
                last_problems = problems
                continue
        params, opt_state, meta = _load_dir(path, params_template, opt_template)
        meta["_fallback_skipped"] = skipped
        meta["_name"] = name
        return int(meta["step"]), params, opt_state, meta
    raise CheckpointCorrupt(
        f"no verified checkpoint in {ckpt_dir}: skipped {skipped}, "
        f"last problems {last_problems}"
    )
