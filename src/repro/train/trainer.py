"""Training loop: jit + shardings, NaN guards, periodic + emergency
checkpointing, automatic resume.  Runs identically on 1 CPU device (examples)
and under the production mesh (launch/train.py).
"""
from __future__ import annotations

import math
import os
import time

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models import lm
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_mod
from repro.train.train_step import make_train_step
from repro.utils.jax_compat import maybe_set_mesh


class Trainer:
    def __init__(
        self,
        cfg,
        opt_cfg: opt_mod.OptimizerConfig,
        dataset,
        *,
        workdir: str,
        mesh=None,
        seed: int = 0,
        log_every: int = 10,
        ckpt_every: int = 200,
        nan_policy: str = "skip",  # skip | halt
    ):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.dataset = dataset
        self.workdir = workdir
        self.mesh = mesh
        self.log_every = log_every
        self.ckpt_every = ckpt_every
        self.nan_policy = nan_policy
        self.ckpt_dir = os.path.join(workdir, "checkpoints")
        os.makedirs(self.ckpt_dir, exist_ok=True)

        key = jax.random.PRNGKey(seed)
        p_shapes = jax.eval_shape(lambda k: lm.init_params(k, cfg), key)
        o_shapes = jax.eval_shape(opt_mod.adamw_init, p_shapes)

        self.step = 0
        resume = ckpt.latest_step(self.ckpt_dir)
        if resume is not None:
            self.step, params, opt_state, meta = ckpt.load_checkpoint(
                self.ckpt_dir, p_shapes, o_shapes
            )
            if meta.get("data_state"):
                self.dataset.restore(meta["data_state"])
            print(f"[trainer] resumed from step {self.step}")
        else:
            params = lm.init_params(key, cfg)
            opt_state = opt_mod.adamw_init(params)

        if mesh is not None:
            axes = lm.param_axes(cfg)
            p_shard = shd.param_shardings(axes, p_shapes, mesh, fsdp=cfg.fsdp)
            o_shard = {
                "m": p_shard,
                "v": p_shard,
                "count": shd.replicated(mesh),
            }
            self.params = jax.tree_util.tree_map(jax.device_put, params, p_shard)
            self.opt_state = jax.tree_util.tree_map(
                jax.device_put, opt_state, o_shard
            )
            self._step_fn = jax.jit(
                make_train_step(cfg, opt_cfg),
                donate_argnums=(0, 1),
            )
        else:
            self.params = params
            self.opt_state = opt_state
            self._step_fn = jax.jit(
                make_train_step(cfg, opt_cfg), donate_argnums=(0, 1)
            )

        self.history: list[dict] = []

    # ------------------------------------------------------------------
    def _checkpoint(self, tag: str = "") -> None:
        ckpt.save_checkpoint(
            self.ckpt_dir,
            self.step,
            self.params,
            self.opt_state,
            self.dataset.state(),
            extra_meta={"tag": tag, "arch": self.cfg.name},
        )

    def run(self, num_steps: int) -> list[dict]:
        target = self.step + num_steps
        try:
            while self.step < target:
                batch = self.dataset.next_batch()
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                t0 = time.perf_counter()
                # The mesh context is what lets trace-time dispatch see the
                # mesh: sharding constraints in the model and the ring
                # context-parallel attention (core.api._active_context_mesh)
                # both read the active mesh.
                with maybe_set_mesh(self.mesh):
                    new_params, new_opt, metrics = self._step_fn(
                        self.params, self.opt_state, batch,
                        jnp.asarray(self.step, jnp.int32),
                    )
                loss = float(metrics["loss"])
                skipped = float(metrics.get("skipped", 0.0)) > 0
                self.params, self.opt_state = new_params, new_opt
                if skipped:
                    # update was suppressed inside the jitted step (NaN guard)
                    if self.nan_policy == "halt":
                        self._checkpoint(tag="nan-halt")
                        raise FloatingPointError(f"NaN loss at step {self.step}")
                    print(f"[trainer] step {self.step}: non-finite loss, skipped")
                dt = time.perf_counter() - t0
                self.step += 1
                rec = {"step": self.step, "loss": loss,
                       "grad_norm": float(metrics["grad_norm"]),
                       "lr": float(metrics["lr"]), "sec": dt}
                self.history.append(rec)
                if self.step % self.log_every == 0:
                    print(
                        f"[trainer] step {rec['step']:>6} "
                        f"loss {rec['loss']:.4f} gnorm {rec['grad_norm']:.3f} "
                        f"lr {rec['lr']:.2e} {dt*1e3:.0f} ms"
                    )
                if self.step % self.ckpt_every == 0:
                    self._checkpoint()
        except KeyboardInterrupt:
            self._checkpoint(tag="interrupt")
            raise
        except Exception:
            # fault tolerance: best-effort emergency save before propagating
            try:
                self._checkpoint(tag="emergency")
            except Exception:
                pass
            raise
        self._checkpoint(tag="final")
        return self.history
