"""Training loop: jit + shardings, NaN guards, anomaly rollback, periodic +
emergency checkpointing, automatic resume.  Runs identically on 1 CPU device
(examples) and under the production mesh (launch/train.py).

Fault-tolerance model (DESIGN.md §Training robustness):

* **NaN guard** (train_step): a non-finite loss/grad-norm suppresses the
  update inside the jitted step; the Trainer counts the skip and either
  continues (``nan_policy="skip"``) or halts with a tagged checkpoint.
* **Anomaly guard** (train.anomaly): an EWMA/z-score detector over the loss
  and grad-norm streams catches *finite* divergence.  On a spike the
  Trainer rolls params+opt back to the last **verified** checkpoint and
  does NOT rewind the data stream — the deterministic stream is already
  positioned past the offending window, so the bad batch is never replayed.
  Consecutive rollbacks without a new checkpoint in between are bounded by
  ``AnomalyConfig.max_rollbacks``; exhausting them raises
  :class:`~repro.train.anomaly.AnomalyHalt` after a ``-anomaly-halt``
  tagged save.
* **Verified resume** (train.checkpoint): construction resumes from the
  newest checkpoint that passes manifest verification, counting any
  torn/corrupt ones it skipped in ``counters["torn_ckpt_fallbacks"]``.
* **Emergency save**: an escaping exception triggers a best-effort
  ``-emergency`` tagged save — tag-suffixed so it can never clobber a good
  periodic checkpoint at the same step — and a save failure is *logged and
  counted*, never silently discarded.
* **Fault injection** (repro.faults): the train-domain points ``nan_grad``,
  ``loss_spike``, ``data_shard_corrupt`` are consulted once per step and
  ``ckpt_torn_write`` once per save, so the chaos suite
  (tests/test_train_chaos.py) can drive every recovery path
  deterministically.
"""
from __future__ import annotations

from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd
from repro.faults import NULL_INJECTOR
from repro.obs.clock import resolve_clock
from repro.obs.trace import get_recorder
from repro.models import lm
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_mod
from repro.train.anomaly import AnomalyConfig, AnomalyDetector, AnomalyHalt
from repro.train.elastic import counters_view
from repro.train.train_step import make_train_step
from repro.utils.jax_compat import maybe_set_mesh

import os


#: Loss/grad-norm multiplier an injected ``loss_spike`` applies when its
#: spec leaves ``scale`` unset.
DEFAULT_SPIKE_SCALE = 64.0


def _scramble_labels(batch: dict, step: int, vocab: int) -> dict:
    """Deterministic stand-in for a corrupt data shard: the labels become
    uniform random tokens (keyed by step), decoupled from the inputs — the
    loss excursion that results is the anomaly guard's to catch."""
    rng = np.random.Generator(np.random.Philox(key=[0xDA7A ^ step, 0]))
    bad = dict(batch)
    labels = np.asarray(batch["labels"])
    bad["labels"] = rng.integers(0, vocab, labels.shape).astype(labels.dtype)
    return bad


class Trainer:
    def __init__(
        self,
        cfg,
        opt_cfg: opt_mod.OptimizerConfig,
        dataset,
        *,
        workdir: str,
        mesh=None,
        seed: int = 0,
        log_every: int = 10,
        ckpt_every: int = 200,
        ckpt_keep: int = 3,
        nan_policy: str = "skip",  # skip | halt
        anomaly: AnomalyConfig | None = None,
        faults=None,
        clock=None,
        trace=None,
    ):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.dataset = dataset
        self.workdir = workdir
        self.mesh = mesh
        self.log_every = log_every
        self.ckpt_every = ckpt_every
        self.ckpt_keep = ckpt_keep
        self.nan_policy = nan_policy
        self.anomaly = anomaly or AnomalyConfig()
        self.faults = faults or NULL_INJECTOR
        self.clock = resolve_clock(clock)
        self.trace = trace if trace is not None else get_recorder()
        self.ckpt_dir = os.path.join(workdir, "checkpoints")
        os.makedirs(self.ckpt_dir, exist_ok=True)

        self.counters: Counter = Counter()
        self._detector = AnomalyDetector(self.anomaly)
        self._ckpts_written = 0
        self._rollback_streak = 0
        self._rollback_ckpt_mark = -1

        key = jax.random.PRNGKey(seed)
        self._p_shapes = jax.eval_shape(lambda k: lm.init_params(k, cfg), key)
        self._o_shapes = jax.eval_shape(opt_mod.adamw_init, self._p_shapes)
        self._step_fn = jax.jit(
            make_train_step(cfg, opt_cfg), donate_argnums=(0, 1)
        )

        self.step = 0
        self.history: list[dict] = []
        if ckpt.latest_step(self.ckpt_dir) is not None:
            step, params, opt_state, meta = ckpt.load_checkpoint(
                self.ckpt_dir, self._p_shapes, self._o_shapes
            )
            self.counters["torn_ckpt_fallbacks"] += meta.get(
                "_fallback_skipped", 0
            )
            self.step = step
            self._set_state(params, opt_state)
            if meta.get("data_state"):
                self.dataset.restore(meta["data_state"])
            print(f"[trainer] resumed from step {self.step} "
                  f"({meta.get('_name')})")
        else:
            params = lm.init_params(key, cfg)
            opt_state = opt_mod.adamw_init(params)
            self._set_state(params, opt_state)
            # Baseline checkpoint: the anomaly guard always has a verified
            # rollback target, even before the first periodic save.
            self._checkpoint()

    # ------------------------------------------------------------------
    def _set_state(self, params, opt_state) -> None:
        """Install (host or device) params/opt, sharded under the mesh."""
        if self.mesh is not None:
            axes = lm.param_axes(self.cfg)
            p_shard = shd.param_shardings(
                axes, self._p_shapes, self.mesh, fsdp=self.cfg.fsdp
            )
            o_shard = {
                "m": p_shard,
                "v": p_shard,
                "count": shd.replicated(self.mesh),
            }
            self.params = jax.tree_util.tree_map(jax.device_put, params, p_shard)
            self.opt_state = jax.tree_util.tree_map(
                jax.device_put, opt_state, o_shard
            )
        else:
            self.params = params
            self.opt_state = opt_state

    def _checkpoint(self, tag: str = "") -> None:
        ckpt.save_checkpoint(
            self.ckpt_dir,
            self.step,
            self.params,
            self.opt_state,
            self.dataset.state(),
            extra_meta={"arch": self.cfg.name},
            keep=self.ckpt_keep,
            tag=tag,
            faults=self.faults,
        )
        self._ckpts_written += 1
        self.trace.instant("ckpt", step=self.step, tag=tag)

    def counters_snapshot(self) -> dict:
        """Robustness counters, zero-filled to the frozen schema
        (train.elastic.COUNTER_KEYS)."""
        return counters_view(self.counters)

    # ------------------------------------------------------------------
    def restore_from_checkpoint(self, *, restore_data: bool = True) -> int:
        """Reload params+opt (and optionally the data cursor) from the
        newest *verified* checkpoint; rewinds ``step`` and trims history.
        ``restore_data=False`` is the anomaly-rollback mode: the data
        stream stays where it is — already advanced past the offending
        window — so the bad batch is never replayed.  Returns the restored
        step."""
        step, params, opt_state, meta = ckpt.load_checkpoint(
            self.ckpt_dir, self._p_shapes, self._o_shapes
        )
        self.counters["torn_ckpt_fallbacks"] += meta.get("_fallback_skipped", 0)
        self.step = step
        self._set_state(params, opt_state)
        if restore_data and meta.get("data_state"):
            self.dataset.restore(meta["data_state"])
        self.history = [r for r in self.history if r["step"] <= step]
        # The detector's EWMA stats are deliberately KEPT: restored params
        # re-live the pre-spike loss regime those stats describe.  Resetting
        # here would let a *persistent* divergence launder itself into the
        # warmup as the new baseline and never flag again.
        return step

    def _rollback_or_halt(self, loss: float, report: dict) -> None:
        """Anomaly response: bounded rollback to the last verified
        checkpoint, else :class:`AnomalyHalt` with a tagged forensic save."""
        if self._ckpts_written > self._rollback_ckpt_mark >= 0:
            # a checkpoint landed since the last rollback — that's forward
            # progress, so the retry budget resets
            self._rollback_streak = 0
        if self._rollback_streak >= self.anomaly.max_rollbacks:
            self.counters["anomaly_halts"] += 1
            self.trace.instant("anomaly_halt", step=self.step)
            self._checkpoint(tag="anomaly-halt")
            raise AnomalyHalt(
                self.step, self._rollback_streak,
                f"loss={loss:.4g}, z={report}",
            )
        self._rollback_streak += 1
        self._rollback_ckpt_mark = self._ckpts_written
        self.counters["rollbacks"] += 1
        at = self.step
        restored = self.restore_from_checkpoint(restore_data=False)
        self.trace.instant("rollback", at=at, restored=restored)
        print(
            f"[trainer] anomaly at step {at} (loss {loss:.4g}, {report}): "
            f"rolled back to step {restored}, data stream advanced past "
            f"the window (retry {self._rollback_streak}/"
            f"{self.anomaly.max_rollbacks})"
        )

    # ------------------------------------------------------------------
    def step_once(self) -> dict | None:
        """One training step with all guards.  Returns the history record,
        or None when the step was consumed by an anomaly rollback (``step``
        then rewound to the restored checkpoint)."""
        with self.trace.span("train/step", step=self.step):
            return self._step_once_inner()

    def _step_once_inner(self) -> dict | None:
        with self.trace.span("data", step=self.step):
            batch = self.dataset.next_batch()
        if self.faults.fires("data_shard_corrupt") is not None:
            batch = _scramble_labels(batch, self.step, self.cfg.vocab)
            self.counters["data_corrupt_batches"] += 1
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        inject = (
            float("nan")
            if self.faults.fires("nan_grad") is not None
            else 0.0
        )
        t0 = self.clock()
        # The mesh context is what lets trace-time dispatch see the
        # mesh: sharding constraints in the model and the ring
        # context-parallel attention (core.api._active_context_mesh)
        # both read the active mesh.
        with self.trace.span("fwd_bwd", step=self.step), \
                maybe_set_mesh(self.mesh):
            new_params, new_opt, metrics = self._step_fn(
                self.params, self.opt_state, batch,
                jnp.asarray(self.step, jnp.int32),
                jnp.asarray(inject, jnp.float32),
            )
        loss = float(metrics["loss"])
        gnorm = float(metrics["grad_norm"])
        spec = self.faults.fires("loss_spike")
        if spec is not None:
            scale = spec.scale if spec.scale > 0 else DEFAULT_SPIKE_SCALE
            loss *= scale
            gnorm *= scale
        skipped = float(metrics.get("skipped", 0.0)) > 0
        self.params, self.opt_state = new_params, new_opt
        if skipped:
            # update was suppressed inside the jitted step (NaN guard)
            self.counters["nan_skips"] += 1
            self.trace.instant("nan_skip", step=self.step)
            if self.nan_policy == "halt":
                self._checkpoint(tag="nan-halt")
                raise FloatingPointError(f"NaN loss at step {self.step}")
            print(f"[trainer] step {self.step}: non-finite loss, skipped")
        else:
            report = self._detector.update(loss, gnorm)
            if report is not None:
                self._rollback_or_halt(loss, report)
                return None
        dt = self.clock() - t0
        self.step += 1
        rec = {"step": self.step, "loss": loss,
               "grad_norm": gnorm,
               "lr": float(metrics["lr"]), "sec": dt}
        self.history.append(rec)
        if self.step % self.log_every == 0:
            print(
                f"[trainer] step {rec['step']:>6} "
                f"loss {rec['loss']:.4f} gnorm {rec['grad_norm']:.3f} "
                f"lr {rec['lr']:.2e} {dt*1e3:.0f} ms"
            )
        if self.step % self.ckpt_every == 0:
            self._checkpoint()
        return rec

    def run(self, num_steps: int) -> list[dict]:
        target = self.step + num_steps
        try:
            while self.step < target:
                self.step_once()
        except KeyboardInterrupt:
            self._checkpoint(tag="interrupt")
            raise
        except (AnomalyHalt, FloatingPointError):
            # already checkpointed under their own tag; no emergency dance
            raise
        except Exception:
            # fault tolerance: best-effort emergency save before
            # propagating.  The tag-suffixed name can never clobber a good
            # periodic checkpoint at the same step, and a failed save is
            # logged + counted — never silently discarded.
            try:
                self._checkpoint(tag="emergency")
                self.counters["emergency_saves"] += 1
                self.trace.instant("emergency_save", step=self.step)
            except Exception as save_err:  # noqa: BLE001
                self.counters["emergency_save_failures"] += 1
                print(
                    f"[trainer] EMERGENCY SAVE FAILED at step {self.step}: "
                    f"{save_err!r}"
                )
            raise
        self._checkpoint(tag="final")
        return self.history
    # run() returns the post-rollback history: records past a rolled-back
    # step are trimmed, so the list always reads as one coherent trajectory.
