"""The jitted train step: loss → grads → clip → AdamW, with optional
microbatch gradient accumulation.

Under the production mesh this function is jitted with in/out shardings from
``repro.distributed.sharding``; DP gradient all-reduces, FSDP all-gathers and
TP collectives all emerge from GSPMD against those shardings.  The same
function runs unsharded on CPU for the end-to-end example.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.train import optimizer as opt_mod
from repro.utils import tree_zeros_like


def make_train_step(cfg, opt_cfg):
    """→ train_step(params, opt_state, batch, step) → (params, opt_state, metrics)."""

    def compute_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lm.loss_fn, has_aux=True
        )(params, cfg, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, batch, step, inject=0.0):
        if opt_cfg.grad_accum > 1:
            # Split the leading batch dim into microbatches and accumulate.
            def split(x):
                b = x.shape[0]
                mb = b // opt_cfg.grad_accum
                return x.reshape((opt_cfg.grad_accum, mb) + x.shape[1:])

            micro = {k: split(v) for k, v in batch.items()}

            def body(carry, mb_batch):
                g_acc, loss_acc = carry
                loss, _, grads = compute_grads(params, mb_batch)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, grads)
                return (g_acc, loss_acc + loss), None

            (grads, loss_sum), _ = jax.lax.scan(
                body, (tree_zeros_like(params), jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree_util.tree_map(
                lambda g: g / opt_cfg.grad_accum, grads
            )
            loss = loss_sum / opt_cfg.grad_accum
            metrics = {}
        else:
            loss, metrics, grads = compute_grads(params, batch)

        # Fault-injection hook (repro.faults point "nan_grad"): the Trainer
        # passes inject=NaN to poison the loss *inside* the jitted step —
        # ``x + NaN*0 = NaN`` — so the injected failure exercises the real
        # NaN-skip path below, not a host-side imitation of it.  The default
        # 0.0 folds away to a no-op.
        loss = loss + inject * 0.0

        grads, gnorm = opt_mod.clip_by_global_norm(grads, opt_cfg.grad_clip)
        lr = opt_mod.schedule(opt_cfg, step)
        new_params, new_opt_state = opt_mod.adamw_update(
            params, grads, opt_state, opt_cfg, lr
        )
        # NaN guard (fault tolerance): a non-finite loss or grad skips the
        # update *inside* the jitted step, so buffer donation stays safe.
        ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)
        pick = lambda n, o: jnp.where(ok, n, o)
        new_params = jax.tree_util.tree_map(pick, new_params, params)
        new_opt_state = jax.tree_util.tree_map(pick, new_opt_state, opt_state)
        out_metrics = {
            "loss": loss.astype(jnp.float32),
            "grad_norm": gnorm,
            "lr": lr,
            "skipped": (~ok).astype(jnp.float32),
            **{k: v.astype(jnp.float32) for k, v in metrics.items()},
        }
        return new_params, new_opt_state, out_metrics

    return train_step


def make_eval_step(cfg):
    def eval_step(params, batch):
        loss, metrics = lm.loss_fn(params, cfg, batch)
        return {"loss": loss, **metrics}

    return eval_step
