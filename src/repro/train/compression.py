"""Int8 error-feedback gradient compression for the DP all-reduce.

At 1000-node scale the data-parallel gradient all-reduce is the largest
cross-pod collective; int8 quantisation cuts its bytes 4× (vs fp32 moments)
at negligible quality cost when the quantisation error is fed back into the
next step (error feedback ⇒ unbiased in the long run).

``compress``/``decompress`` are pure and tested for the EF contract
(residual-corrected round trip recovers the signal); ``ef_pmean`` is the
shard_map building block applying them around a pmean.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantisation → (q, scale)."""
    amax = jnp.max(jnp.abs(g)).astype(jnp.float32)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_step(g: jnp.ndarray, residual: jnp.ndarray):
    """One error-feedback step → (quantised payload, new_residual).

    payload decompresses to ≈ (g + residual); the new residual carries the
    quantisation error into the next step.
    """
    corrected = g.astype(jnp.float32) + residual
    q, scale = compress(corrected)
    deq = decompress(q, scale)
    return (q, scale), corrected - deq


def ef_pmean(grads, residuals, axis_name: str):
    """Inside shard_map/pmap: error-feedback-compressed gradient mean over
    ``axis_name``.  Returns (mean_grads, new_residuals).

    The int8 payload is what crosses the wire (4× fewer DP bytes than fp32);
    scales are all-gathered implicitly via the f32 pmean of the tiny scalars.
    """

    def one(g, r):
        (q, scale), new_r = ef_step(g, r)
        deq = decompress(q, scale)
        return jax.lax.pmean(deq, axis_name), new_r

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    mean_grads = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_res = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return mean_grads, new_res


def init_residuals(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
