"""Data pipeline: deterministic synthetic streams + binary token shards.

Both sources are (a) deterministic given (seed, step) so a restarted job
resumes bit-identically, (b) host-shardable for multi-host training, and
(c) stateful with an explicit, checkpointable ``state()`` dict.
"""
from __future__ import annotations

import json
import os

import numpy as np


class SyntheticLMData:
    """Deterministic synthetic token stream (Philox keyed by (seed, step)).

    Draws structured sequences (a noisy integer-sequence task) rather than
    i.i.d. tokens so training loss actually decreases — used by the
    end-to-end example and convergence tests.
    """

    def __init__(self, vocab: int, batch: int, seq_len: int, *, seed: int = 0,
                 host_id: int = 0, num_hosts: int = 1):
        self.vocab = vocab
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.host_id = host_id
        self.num_hosts = num_hosts
        self._step = 0

    def state(self) -> dict:
        return {"step": self._step, "seed": self.seed}

    def restore(self, state: dict) -> None:
        self._step = int(state["step"])
        self.seed = int(state["seed"])

    def _rng(self, step: int) -> np.random.Generator:
        key = (self.seed << 32) ^ (step << 8) ^ self.host_id
        return np.random.Generator(np.random.Philox(key=[key, 0]))

    def next_batch(self) -> dict:
        rng = self._rng(self._step)
        self._step += 1
        b, s, v = self.batch, self.seq_len + 1, self.vocab
        # arithmetic sequences mod vocab with token noise — learnable structure
        start = rng.integers(0, v, (b, 1))
        stride = rng.integers(1, 7, (b, 1))
        seq = (start + stride * np.arange(s)[None, :]) % v
        noise = rng.random((b, s)) < 0.05
        seq = np.where(noise, rng.integers(0, v, (b, s)), seq)
        seq = seq.astype(np.int32)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}


class BinaryShardData:
    """Flat binary token shards (np.uint16/uint32 .bin files).

    Layout-compatible with common LM pretraining dumps.  Hosts stride over
    documents; the cursor state is checkpointable for exact resume.
    """

    def __init__(self, paths: list[str], batch: int, seq_len: int, *,
                 dtype=np.uint16, host_id: int = 0, num_hosts: int = 1,
                 seed: int = 0):
        if not paths:
            raise ValueError("no shard paths given")
        self.paths = sorted(paths)
        self.batch = batch
        self.seq_len = seq_len
        self.dtype = np.dtype(dtype)
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.seed = seed
        self._shard_idx = 0
        self._offset = host_id * batch * (seq_len + 1)
        self._epoch = 0
        self._mm = None
        self._open()

    def _open(self):
        self._mm = np.memmap(self.paths[self._shard_idx], dtype=self.dtype,
                             mode="r")

    def state(self) -> dict:
        return {
            "shard_idx": self._shard_idx,
            "offset": int(self._offset),
            "epoch": self._epoch,
        }

    def restore(self, state: dict) -> None:
        self._shard_idx = int(state["shard_idx"])
        self._offset = int(state["offset"])
        self._epoch = int(state["epoch"])
        self._open()

    def next_batch(self) -> dict:
        need = self.batch * (self.seq_len + 1)
        stride = need * self.num_hosts
        if self._offset + need > len(self._mm):
            self._shard_idx = (self._shard_idx + 1) % len(self.paths)
            if self._shard_idx == 0:
                self._epoch += 1
            self._offset = self.host_id * need
            self._open()
        flat = np.asarray(self._mm[self._offset : self._offset + need],
                          dtype=np.int32)
        self._offset += stride
        seq = flat.reshape(self.batch, self.seq_len + 1)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}


def write_binary_shard(path: str, tokens: np.ndarray, dtype=np.uint16) -> None:
    """Helper used by examples/tests to produce shard files."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tokens.astype(dtype).tofile(path)


def save_data_state(path: str, state: dict) -> None:
    with open(path, "w") as f:
        json.dump(state, f)


def load_data_state(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
