"""Loss/grad-norm anomaly detection for the training loop.

The in-step NaN guard (train_step) only catches *non-finite* blow-ups; a
silently diverging run — loss spiking 100× while staying finite — sails
through it and poisons every later step.  The :class:`AnomalyDetector`
watches the loss and grad-norm streams with an EWMA mean/variance and flags
a sample whose one-sided z-score exceeds ``z_threshold`` — the Trainer then
rolls params+opt back to the last *verified* checkpoint and advances the
deterministic data stream past the offending window (DESIGN.md §Training
robustness).

Design notes:

* **One-sided** — only upward excursions flag; a loss cliff downward is
  suspicious but not damaging, and flagging it would fight convergence.
* **Spikes are not absorbed** — a flagged sample does not update the EWMA
  statistics, so a divergence cannot drag the baseline up after itself and
  mask its own continuation.
* **Warmup** — the first ``warmup`` samples only feed the statistics; early
  training is legitimately volatile and the variance estimate needs mass
  before z-scores mean anything.
* **Bounded retries** — the Trainer tracks consecutive rollbacks that made
  no forward progress and raises :class:`AnomalyHalt` after
  ``max_rollbacks``: a persistently bad region halts loudly instead of
  looping rollback→spike forever.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


class AnomalyHalt(RuntimeError):
    """Rollback retries exhausted: the run is halted with a tagged
    checkpoint on disk rather than looping over a persistently bad
    region."""

    def __init__(self, step: int, rollbacks: int, detail: str = ""):
        self.step = step
        self.rollbacks = rollbacks
        super().__init__(
            f"anomaly guard halted training at step {step} after "
            f"{rollbacks} rollback(s) without progress"
            + (f": {detail}" if detail else "")
        )


@dataclass(frozen=True)
class AnomalyConfig:
    """Knobs for the Trainer's anomaly guard.

    ``z_threshold`` is deliberately loose by default (8σ): the guard exists
    to catch divergence, not to second-guess ordinary gradient noise.
    ``min_rel_increase`` is an absolute backstop under near-zero variance —
    a perfectly flat loss plateau would otherwise flag on femto-scale
    jitter.  ``max_rollbacks`` bounds consecutive no-progress rollbacks
    before :class:`AnomalyHalt`.
    """

    enabled: bool = True
    z_threshold: float = 8.0
    ewma_alpha: float = 0.1
    warmup: int = 20
    min_rel_increase: float = 0.25
    max_rollbacks: int = 3


class AnomalyDetector:
    """EWMA mean/variance z-score detector over (loss, grad_norm)."""

    def __init__(self, cfg: AnomalyConfig | None = None):
        self.cfg = cfg or AnomalyConfig()
        self.reset()

    def reset(self) -> None:
        """Forget all statistics.  NOT called on rollback — the restored
        params re-live the regime the current stats describe, and resetting
        would let a persistent divergence launder itself into the fresh
        warmup as the new baseline."""
        self._stats = {"loss": [None, 0.0, 0], "grad_norm": [None, 0.0, 0]}

    def _update_one(self, name: str, x: float) -> float | None:
        """Feed one sample; returns the z-score when it flags, else None."""
        mean, var, n = self._stats[name]
        if mean is None:
            self._stats[name] = [x, 0.0, 1]
            return None
        sigma = math.sqrt(var)
        z = (x - mean) / sigma if sigma > 0 else float("inf")
        flagged = (
            n >= self.cfg.warmup
            and x > mean * (1.0 + self.cfg.min_rel_increase)
            and z > self.cfg.z_threshold
        )
        if not flagged:
            a = self.cfg.ewma_alpha
            delta = x - mean
            mean = mean + a * delta
            # EW variance of the residual stream (West 1979 style):
            var = (1 - a) * (var + a * delta * delta)
            self._stats[name] = [mean, var, n + 1]
            return None
        return z

    def update(self, loss: float, grad_norm: float) -> dict | None:
        """Feed one step's scalars; returns a spike report dict when either
        signal flags (the sample is then NOT absorbed), else None.  Callers
        should gate non-finite values through the NaN guard first."""
        if not self.cfg.enabled:
            return None
        report = {}
        z = self._update_one("loss", loss)
        if z is not None:
            report["loss_z"] = z
        z = self._update_one("grad_norm", grad_norm)
        if z is not None:
            report["grad_norm_z"] = z
        return report or None
