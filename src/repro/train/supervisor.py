"""Elastic training supervisor: heartbeats, remesh, restore, stragglers.

``TrainSupervisor`` wires the (previously dead) control-plane machinery in
:mod:`repro.train.elastic` into a live :class:`~repro.train.trainer.Trainer`
under a **simulated multi-worker harness** — the training analog of the
serving tier's ``ClusterRouter``.  One supervisor tick is one heartbeat
interval AND one training step:

1. consult the shared fault points ``worker_loss`` / ``slow_worker``
   (``uid`` = the worker id) — a crashed worker stops heartbeating for
   good, a slow one reports an inflated step time;
2. feed surviving heartbeats to the :class:`FailureDetector` and the
   per-worker step times to a :class:`StragglerTracker` — a worker flagged
   ``patience`` consecutive times is *excluded* (stops being heartbeat, so
   it drains through the same death path);
3. on newly-dead workers: ``replan_mesh`` to the survivor count,
   ``reassign_shards`` deterministically, and restore the Trainer from the
   last **verified** checkpoint (checkpoints are mesh-agnostic, so the
   shrunken plan re-shards on device_put) — then continue;
4. run one guarded training step (``Trainer.step_once`` — NaN skip,
   anomaly rollback, periodic checkpoint all apply).

On this container the workers are simulated (the real mesh is whatever the
Trainer was built with), but every decision the supervisor makes — death
detection, replan shapes, shard reassignment, restore-and-continue — is the
deterministic production logic, driven tick-by-tick by the chaos suite
(tests/test_train_chaos.py).  With an intact device count the post-recovery
loss trajectory is bit-identical to an uninterrupted run: restore replays
params+opt+data from the checkpoint and the data stream is deterministic.

``counters_snapshot()`` follows the frozen ``train.elastic.COUNTER_KEYS``
schema (the lifecycle.COUNTER_KEYS pattern), merging the Trainer's own
counters with the supervisor's remesh/straggler bookkeeping.
"""
from __future__ import annotations

from collections import Counter

from repro.faults import NULL_INJECTOR
from repro.obs.trace import get_recorder
from repro.train.elastic import (
    COUNTER_KEYS,
    FailureDetector,
    StragglerPolicy,
    StragglerTracker,
    counters_view,
    reassign_shards,
    replan_mesh,
)


class NoSurvivorsError(RuntimeError):
    """Every worker died; the job cannot continue (the last verified
    checkpoint on disk is the restart point)."""


class TrainSupervisor:
    """Drives a Trainer under simulated elastic membership.

    ``trainer`` needs the Trainer surface: ``step``, ``step_once()``,
    ``restore_from_checkpoint()``, ``counters``; the chaos suite also runs
    a lightweight fake through here.  ``clock`` is injectable and only
    used to timestamp events (ticks are the logical time base).
    """

    def __init__(
        self,
        trainer,
        *,
        num_workers: int = 4,
        model_parallel: int = 1,
        num_shards: int | None = None,
        max_missed: int = 3,
        straggler_policy: StragglerPolicy | None = None,
        base_step_time: float = 1.0,
        faults=None,
        clock=None,
        trace=None,
    ):
        if num_workers < 1:
            raise ValueError("need at least one worker")
        self.trainer = trainer
        self.model_parallel = model_parallel
        self.num_shards = num_shards or 2 * num_workers
        self.base_step_time = base_step_time
        self.faults = faults or NULL_INJECTOR
        self.clock = clock or (lambda: float(self.ticks))
        self.trace = trace if trace is not None else get_recorder()
        self.ticks = 0
        self.counters: Counter = Counter()
        self.detector = FailureDetector(
            list(range(num_workers)), max_missed=max_missed
        )
        self.straggler = StragglerTracker(straggler_policy or StragglerPolicy())
        #: workers that crashed / were excluded — they never heartbeat again
        self.lost: set[int] = set()
        self.mesh_plan = replan_mesh(
            num_workers * model_parallel, model_parallel=model_parallel
        )
        self.shard_assignment = reassign_shards(
            self.num_shards, self.detector.alive
        )
        self.events: list[dict] = []

    # ------------------------------------------------------------------
    @property
    def alive(self) -> list[int]:
        return self.detector.alive

    def counters_snapshot(self) -> dict:
        """Merged Trainer + supervisor robustness counters, zero-filled to
        the frozen train.elastic.COUNTER_KEYS schema."""
        merged = Counter(getattr(self.trainer, "counters", {}))
        merged.update(self.counters)
        return counters_view(merged)

    # ------------------------------------------------------------------
    def _handle_deaths(self, dead: list[int]) -> None:
        for w in dead:
            self.straggler.forget(w)
        self.counters["worker_deaths"] += len(dead)
        survivors = self.detector.alive
        if not survivors:
            raise NoSurvivorsError(
                f"all workers dead at tick {self.ticks}; restart from the "
                "last verified checkpoint"
            )
        self.counters["remesh_events"] += 1
        self.mesh_plan = replan_mesh(
            len(survivors) * self.model_parallel,
            model_parallel=self.model_parallel,
        )
        self.shard_assignment = reassign_shards(self.num_shards, survivors)
        restored = self.trainer.restore_from_checkpoint()
        self.events.append({
            "tick": self.ticks, "t": self.clock(), "kind": "remesh",
            "dead": sorted(dead), "survivors": survivors,
            "mesh": self.mesh_plan[0], "restored_step": restored,
        })
        self.trace.instant("remesh", tick=self.ticks, dead=sorted(dead),
                           survivors=len(survivors), restored_step=restored)
        print(
            f"[supervisor] tick {self.ticks}: workers {sorted(dead)} lost; "
            f"remeshed to {self.mesh_plan[0]} over {len(survivors)} "
            f"worker(s), restored from verified step {restored}"
        )

    def tick(self) -> dict | None:
        """One heartbeat interval + one training step.  Returns the
        Trainer's history record (None when the step was consumed by an
        anomaly rollback)."""
        self.ticks += 1
        # 1) membership faults: a crashed worker never beats again
        for w in list(self.detector.alive):
            if w not in self.lost and (
                self.faults.fires("worker_loss", uid=w) is not None
            ):
                self.lost.add(w)
                self.events.append({
                    "tick": self.ticks, "t": self.clock(),
                    "kind": "worker_loss", "worker": w,
                })
                self.trace.instant("worker_loss", tick=self.ticks, worker=w)
        # 2) step-time reports from workers that are still responsive
        step_times = {}
        for w in self.detector.alive:
            if w in self.lost:
                continue
            t = self.base_step_time
            spec = self.faults.fires("slow_worker", uid=w)
            if spec is not None:
                t += spec.delay if spec.delay > 0 else self.base_step_time * 4
            step_times[w] = t
        flagged, to_exclude = self.straggler.observe(step_times)
        self.counters["straggler_flags"] += len(flagged)
        for w in to_exclude:
            # a persistent straggler is excluded: it stops being heartbeat,
            # so it drains through the same detector-death → remesh path a
            # crash does (one recovery mechanism, not two)
            self.lost.add(w)
            self.events.append({
                "tick": self.ticks, "t": self.clock(),
                "kind": "straggler_excluded", "worker": w,
            })
            self.trace.instant("straggler_excluded", tick=self.ticks,
                               worker=w)
        # 3) heartbeats + death detection
        for w in step_times:
            if w not in self.lost:
                self.detector.beat(w)
        dead = self.detector.tick()
        if dead:
            self._handle_deaths(dead)
        # 4) one guarded training step
        return self.trainer.step_once()

    def run(self, num_steps: int, *, max_ticks: int | None = None) -> list[dict]:
        """Advance the Trainer ``num_steps`` beyond its current step, under
        supervision.  Rollbacks/restores rewind the Trainer, so the tick
        count can exceed ``num_steps``; ``max_ticks`` (default 10×) bounds
        a pathological loop the same way the serve engines' step budgets
        do."""
        target = self.trainer.step + num_steps
        budget = self.ticks + (max_ticks if max_ticks is not None
                               else 10 * num_steps)
        while self.trainer.step < target:
            if self.ticks >= budget:
                raise RuntimeError(
                    f"supervisor exhausted {budget} ticks with the trainer "
                    f"at step {self.trainer.step} < target {target}"
                )
            self.tick()
        return self.trainer.history
