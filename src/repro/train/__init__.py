"""Training substrate: optimizer, data, checkpointing, compression, trainer."""
from repro.train import (
    checkpoint,
    compression,
    data,
    elastic,
    optimizer,
    train_step,
    trainer,
)

__all__ = [
    "checkpoint",
    "compression",
    "data",
    "elastic",
    "optimizer",
    "train_step",
    "trainer",
]
