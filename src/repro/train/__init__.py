"""Training substrate: optimizer, data, checkpointing, compression, trainer,
anomaly guard, elastic supervisor."""
from repro.train import (
    anomaly,
    checkpoint,
    compression,
    data,
    elastic,
    optimizer,
    supervisor,
    train_step,
    trainer,
)

__all__ = [
    "anomaly",
    "checkpoint",
    "compression",
    "data",
    "elastic",
    "optimizer",
    "supervisor",
    "train_step",
    "trainer",
]
