"""The tuned-block-size record threaded through every kernel call site.

One frozen (hashable) dataclass covers the distinct block knobs the kernels
actually expose:

  * forward (l, m) = ``(block_q, block_k)`` — flash & distr fwd kernels;
  * backward dQ kernel blocks — the dQ kernel streams K/V per Q block, so
    its optimal tile differs from the dKV kernel, which streams Q/dO per KV
    block and additionally keeps a dK *and* dV accumulator resident;
  * backward dKV kernel blocks;
  * decode split-K ``block_k`` (the split length; ``num_splits`` is derived
    from the cache capacity and kept for reporting).

``None`` fields fall back to the forward pair, so a bare
``BlockSizes(128, 128)`` reproduces the pre-autotuner behaviour exactly.
Being frozen it is a valid ``jax.jit`` static argument and rides through
``custom_vjp`` nondiff args — the backward blocks travel as static
metadata, not as residuals.
"""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class BlockSizes:
    block_q: int = 128
    block_k: int = 128
    # Backward dQ kernel (None → fwd pair).
    block_q_dq: int | None = None
    block_k_dq: int | None = None
    # Backward dKV kernel (None → fwd pair).
    block_q_dkv: int | None = None
    block_k_dkv: int | None = None
    # Decode split-K: split length along the KV axis (None → 128).
    block_k_decode: int | None = None
    # Derived, informational: ceil(cache_len / block_k_decode) at tune time.
    num_splits: int | None = None

    # -- concrete accessors -------------------------------------------------
    def fwd(self) -> tuple[int, int]:
        return (self.block_q, self.block_k)

    def dq(self) -> tuple[int, int]:
        return (
            self.block_q_dq if self.block_q_dq is not None else self.block_q,
            self.block_k_dq if self.block_k_dq is not None else self.block_k,
        )

    def dkv(self) -> tuple[int, int]:
        return (
            self.block_q_dkv if self.block_q_dkv is not None else self.block_q,
            self.block_k_dkv if self.block_k_dkv is not None else self.block_k,
        )

    def decode(self) -> int:
        return self.block_k_decode if self.block_k_decode is not None else 128

    def with_(self, **kw) -> "BlockSizes":
        return replace(self, **kw)

    @staticmethod
    def from_pair(block_q: int, block_k: int) -> "BlockSizes":
        return BlockSizes(block_q=int(block_q), block_k=int(block_k))
