"""The empirical block-size autotuner.

Three-stage resolution for "auto" (``None``) block sizes, selected by the
``REPRO_TUNE`` env var:

  off       static defaults (128×128 / decode 128) — the pre-tuner behaviour.
  analytic  the paper's §3.3.1 rule (``core.block_size.select_block_sizes``),
            clamped to the sequence bucket.  Zero measurement cost.
  measure   enumerate candidates with the analytic model *as a pruner* (all
            VMEM-fitting tiles, ranked by the paper's max-l-then-m objective,
            top-K kept, default 128×128 always included), time each on the
            live backend, cache the winner in the persistent JSON cache.

Measurement runs at the key's sequence bucket (capped in interpret mode —
CPU-interpreter wall time at 4k tokens is pure overhead) on synthetic
inputs, with warmup and ``block_until_ready``; the timer is injectable so
tests are deterministic.  Resolutions are memoised per (mode, cache-path,
key), so a jitted train/serve step pays the sweep once per process and the
JSON cache makes later processes pay nothing.
"""
from __future__ import annotations

import functools
import os

from repro.core.block_size import enumerate_block_sizes, select_block_sizes
from repro.obs.trace import get_recorder
from repro.tune.block_sizes import BlockSizes
from repro.tune.cache import TuneCache, cache_key, seq_bucket
from repro.tune.measure import Timer, measure_candidates, wall_timer

MODES = ("off", "analytic", "measure")
DEFAULT_BLOCK = 128
TOP_K = 8
# Interpreter-mode measurement cap: beyond this the sweep cost dwarfs the
# information (the relative ordering is stable in the bucket); compiled
# backends measure the true bucket up to 2k.
MEASURE_SEQ_CAP_INTERPRET = 512
MEASURE_SEQ_CAP_COMPILED = 2048


def tune_mode() -> str:
    mode = os.environ.get("REPRO_TUNE", "off").lower()
    if mode not in MODES:
        raise ValueError(f"REPRO_TUNE={mode!r}; choose from {MODES}")
    return mode


def _default_interpret() -> bool:
    import jax

    return jax.default_backend() != "tpu"


def _backend_tag(interpret: bool) -> str:
    import jax

    return f"{jax.default_backend()}:{'interpret' if interpret else 'compiled'}"


# ---------------------------------------------------------------------------
# Candidate spaces — sourced from the analytic model (the pruner)
# ---------------------------------------------------------------------------


def pair_candidates(
    d: int,
    *,
    n: int,
    group_size: int = 1,
    top_k: int = TOP_K,
    max_block: int = 1024,
) -> list[tuple[int, int]]:
    """Top-K (l, m) candidates: every VMEM-fitting tile from
    ``enumerate_block_sizes``, ranked by the paper's objective (max l —
    minimum HBM I/O — then max m), clamped to the sequence bucket, deduped.
    The 128×128 default is always appended so a measured pick can never be
    *worse* than the static default on the measured axis."""
    nb = min(seq_bucket(n), max_block)
    legal = enumerate_block_sizes(
        d, group_size=group_size, max_l=max_block, max_m=max_block
    )
    ranked = sorted(legal, key=lambda t: (-t[0], -t[1]))
    cands: list[tuple[int, int]] = []
    for l, m, _ws in ranked:
        c = (min(l, nb), min(m, nb))
        if c not in cands:
            cands.append(c)
        if len(cands) >= top_k:
            break
    default = (min(DEFAULT_BLOCK, nb), min(DEFAULT_BLOCK, nb))
    if default not in cands:
        cands.append(default)
    return cands


def distr_bwd_candidates(
    d: int,
    *,
    block_q: int,
    n: int,
    group_size: int,
    top_k: int = TOP_K,
    max_block: int = 1024,
) -> list[int]:
    """``block_k`` candidates for the distr backward kernels with ``block_q``
    *pinned* (it is the LSH grouping granularity — never swept): the legal
    m values at l = block_q from the analytic VMEM model, largest first,
    clamped to the sequence bucket, 128 always included."""
    nb = min(seq_bucket(n), max_block)
    legal = enumerate_block_sizes(
        d, group_size=group_size, max_l=max_block, max_m=max_block
    )
    ms = sorted(
        {min(m, nb) for l, m, _ws in legal if l == block_q}, reverse=True
    )[:top_k]
    default = min(DEFAULT_BLOCK, nb)
    if default not in ms:
        ms.append(default)
    return ms or [default]


def decode_candidates(n: int, *, max_block: int = 1024) -> list[int]:
    """Split-K decode block_k candidates: power-of-two split lengths up to
    the cache capacity.  Fewer, longer splits amortise per-split overhead;
    more, shorter splits add parallelism — the right point is empirical."""
    nb = min(seq_bucket(n), max_block)
    cands = [bk for bk in (64, 128, 256, 512, 1024) if bk <= nb]
    return cands or [nb]


def paged_block_candidates(n: int, *, max_block: int = 512) -> list[int]:
    """Pool block-size candidates for the paged decode kernel.  The block
    size is simultaneously the DMA granularity (bigger amortises per-block
    overhead) and the allocator granularity (smaller wastes less of the
    last, part-filled block per request) — the right point is empirical,
    measured on the kernel side here; the fragmentation side is workload
    policy (serve/scheduler.py)."""
    nb = min(seq_bucket(n), max_block)
    cands = [bs for bs in (64, 128, 256, 512) if bs <= nb]
    return cands or [nb]


def _make_run_paged_decode(n, d, dtype, interpret, group_size):
    """Sweep runner: one request whose block table spans the whole capacity
    ``n`` — physical blocks deliberately shuffled so the measurement sees
    real (non-contiguous) table indirection."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    dt = _np_dtype(dtype)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    hkv, hq = 1, 2
    q = jax.random.normal(ks[0], (1, hq, 1, d), jnp.float32).astype(dt)
    if group_size > 1:
        from repro.core import grouping

        perm = jnp.broadcast_to(
            jax.random.permutation(jax.random.PRNGKey(1), d)[None], (hkv, d)
        ).astype(jnp.int32)

    def make_run(cand):
        bs = int(cand)
        mb = -(-n // bs)
        p = mb + 1  # + the reserved garbage block
        k_pool = jax.random.normal(
            ks[1], (p, hkv, bs, d), jnp.float32
        ).astype(dt)
        v_pool = jax.random.normal(
            ks[2], (p, hkv, bs, d), jnp.float32
        ).astype(dt)
        bt = jax.random.permutation(
            jax.random.PRNGKey(2), jnp.arange(1, p, dtype=jnp.int32)
        )[None, :]
        lengths = jnp.full((1,), n, jnp.int32)
        if group_size > 1:
            from repro.core import grouping

            k_fused = grouping.fuse_columns(
                k_pool.astype(jnp.float32), perm[None], group_size
            ).astype(dt)
            return lambda: ops.paged_decode_attention(
                q, None, v_pool, block_tables=bt, lengths=lengths,
                k_fused_pool=k_fused, perm=perm, group_size=group_size,
                interpret=interpret,
            )
        return lambda: ops.paged_decode_attention(
            q, k_pool, v_pool, block_tables=bt, lengths=lengths,
            interpret=interpret,
        )

    return make_run


def _analytic_pair(d: int, *, n: int, group_size: int) -> tuple[int, int]:
    nb = min(seq_bucket(n), 1024)
    l, m = select_block_sizes(d, group_size=group_size, max_l=nb, max_m=nb)
    return (min(l, nb), min(m, nb))


def _analytic_decode(n: int) -> int:
    # Aim for ~8 live splits (enough grid parallelism) but never below the
    # 128-lane tile; clamp to the capacity bucket.
    nb = min(seq_bucket(n), 1024)
    bk = 128
    while bk * 8 < nb:
        bk *= 2
    return min(bk, nb, 512)


# ---------------------------------------------------------------------------
# Measurement factories (one per kernel key)
# ---------------------------------------------------------------------------


def _np_dtype(dtype: str):
    import jax.numpy as jnp

    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}.get(
        dtype, jnp.float32
    )


def _qkv(n: int, d: int, dtype: str, *, heads: int = 1):
    import jax
    import jax.numpy as jnp

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    shape = (1, heads, n, d)
    dt = _np_dtype(dtype)
    return tuple(
        jax.random.normal(k, shape, jnp.float32).astype(dt) for k in ks
    )


def _pad_axis(x, block: int, axis: int, value: float = 0.0):
    import jax.numpy as jnp

    pad = (-x.shape[axis]) % block
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _make_run_flash_fwd(n, d, dtype, causal, interpret):
    from repro.kernels import ops

    q, k, v = _qkv(n, d, dtype)

    def make_run(cand):
        bq, bk = cand

        def run():
            return ops.flash_attention(
                q, k, v, causal=causal, block_q=bq, block_k=bk,
                interpret=interpret,
            )

        return run

    return make_run


def _make_run_xla_flash(n, d, dtype, causal, interpret):
    del interpret  # pure-XLA path
    import jax

    from repro.core.flash_reference import blockwise_flash_reference

    q, k, v = _qkv(n, d, dtype)

    def make_run(cand):
        bq, bk = cand
        fn = jax.jit(
            functools.partial(
                blockwise_flash_reference, block_q=bq, block_k=bk,
                causal=causal,
            )
        )
        return lambda: fn(q, k, v)

    return make_run


def _make_run_distr(n, d, dtype, causal, interpret, group_size, *, xla: bool):
    from dataclasses import replace as dc_replace

    from repro.core.distr_attention import DistrConfig

    q, k, v = _qkv(n, d, dtype)
    base = DistrConfig(group_size=group_size)

    def make_run(cand):
        bq, bk = cand
        cfg = dc_replace(base, block_q=bq, block_k=bk)
        if xla:
            import jax

            from repro.core.distr_attention import distr_attention as core_distr

            fn = jax.jit(
                functools.partial(core_distr, cfg=cfg, causal=causal)
            )
            return lambda: fn(q, k, v)
        from repro.kernels import ops

        return lambda: ops.distr_attention(
            q, k, v, cfg, causal=causal, interpret=interpret
        )

    return make_run


def _flash_bwd_inputs(n, d, dtype, causal, interpret):
    """Shared residuals for the dQ/dKV sweeps: one fwd pass at the default
    blocks provides (O, LSE); Δ comes from the delta kernel."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import backward as bwd
    from repro.kernels import ops

    q, k, v = _qkv(n, d, dtype)
    scale = 1.0 / (d**0.5)
    out, lse = ops._flash_fwd_impl(  # noqa: SLF001 — same package family
        causal, scale, min(DEFAULT_BLOCK, n), min(DEFAULT_BLOCK, n),
        interpret, q, k, v, with_residuals=True,
    )
    do = jax.random.normal(jax.random.PRNGKey(7), out.shape, jnp.float32)
    qf = q.reshape(-1, n, d)
    kf = k.reshape(-1, n, d)
    vf = v.reshape(-1, n, d)
    dof = do.reshape(-1, n, d).astype(q.dtype)
    of = out.reshape(-1, n, d)
    delta = bwd.delta_kernel_call(
        of, dof, block_q=min(DEFAULT_BLOCK, n), interpret=interpret
    )
    return qf, kf, vf, dof, lse[:, :n], delta[:, :n], scale


def _make_run_flash_bwd(n, d, dtype, causal, interpret, *, which: str):
    import jax

    from repro.kernels import backward as bwd
    # Residual padding MUST be the production backward's own helpers
    # (ops._pad_rows / ops.LSE_PAD): the sweep times exactly the
    # computation the tuned blocks will run.
    from repro.kernels import ops

    qf, kf, vf, dof, lse, delta, scale = _flash_bwd_inputs(
        n, d, dtype, causal, interpret
    )

    def make_run(cand):
        bq, bk = cand
        qp = _pad_axis(qf, bq, 1)
        dop = _pad_axis(dof, bq, 1)
        lsep = ops._pad_rows(lse, bq, ops.LSE_PAD)
        deltap = ops._pad_rows(delta, bq)
        kp = _pad_axis(kf, bk, 1)
        vp = _pad_axis(vf, bk, 1)
        call = (
            bwd.flash_dq_kernel_call if which == "dq"
            else bwd.flash_dkv_kernel_call
        )
        fn = jax.jit(
            lambda a, b, c, e, f, g: call(
                a, b, c, e, f, g, q_per_kv=1, scale=scale, causal=causal,
                block_q=bq, block_k=bk, kv_len=n, interpret=interpret,
            )
        )
        return lambda: fn(qp, kp, vp, dop, lsep, deltap)

    return make_run


def _make_run_distr_bwd(n, d, dtype, causal, interpret, group_size, block_q,
                        *, which: str):
    """Sweep runner for the distr backward kernels: one fwd pass at the
    pinned block_q provides (O, LSE, Q̂, perms); only ``block_k`` varies."""
    from dataclasses import replace as dc_replace

    import jax
    import jax.numpy as jnp

    from repro.core.distr_attention import DistrConfig
    from repro.kernels import backward as bwd
    from repro.kernels import ops

    q, k, v = _qkv(n, d, dtype)
    scale = 1.0 / (d**0.5)
    cfg = dc_replace(
        DistrConfig(group_size=group_size), block_q=min(block_q, n),
        block_k=min(DEFAULT_BLOCK, n),
    )
    out, lse, q_hat, perms = ops._distr_fwd_impl(  # noqa: SLF001
        cfg, causal, scale, interpret, q, k, v, with_residuals=True,
    )
    do = jax.random.normal(jax.random.PRNGKey(7), out.shape, jnp.float32)
    dof = do.reshape(-1, n, d).astype(q.dtype)
    of = out.reshape(-1, n, d)
    kf = k.reshape(-1, n, d)
    vf = v.reshape(-1, n, d)
    perm_f = perms.reshape(1, -1, d)
    inv_perm_f = jnp.argsort(perm_f, axis=-1).astype(perm_f.dtype)
    delta = bwd.delta_kernel_call(
        of, dof, block_q=cfg.block_q, interpret=interpret
    )

    def make_run(cand):
        bk = int(cand)
        kp = _pad_axis(kf, bk, 1)
        vp = _pad_axis(vf, bk, 1)
        kw = dict(
            q_per_kv=1, causal=causal, group_size=group_size,
            block_q=cfg.block_q, block_k=bk, kv_len=n, interpret=interpret,
        )
        if which == "dq":
            fn = jax.jit(lambda: bwd.distr_dq_kernel_call(
                q_hat, kp, vp, perm_f, dof, lse, delta, **kw
            ))
        else:
            fn = jax.jit(lambda: bwd.distr_dkv_kernel_call(
                q_hat, kp, vp, perm_f, inv_perm_f, dof, lse, delta, **kw
            ))
        return fn

    return make_run


def _make_run_decode(n, d, dtype, interpret, group_size):
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    dt = _np_dtype(dtype)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    hkv, hq = 1, 2
    q = jax.random.normal(ks[0], (1, hq, 1, d), jnp.float32).astype(dt)
    k = jax.random.normal(ks[1], (1, hkv, n, d), jnp.float32).astype(dt)
    v = jax.random.normal(ks[2], (1, hkv, n, d), jnp.float32).astype(dt)
    if group_size > 1:
        # Fused-K̂ layout: narrow score stream, full-width V.
        perm = jnp.broadcast_to(
            jax.random.permutation(jax.random.PRNGKey(1), d)[None], (hkv, d)
        ).astype(jnp.int32)
        from repro.core import grouping

        k_fused = grouping.fuse_columns(
            k.astype(jnp.float32), perm[None], group_size
        ).astype(dt)
    lengths = jnp.full((1,), n, jnp.int32)

    def make_run(cand):
        bk = int(cand)
        if group_size > 1:
            return lambda: ops.decode_attention(
                q, None, v, lengths=lengths, k_fused=k_fused, perm=perm,
                group_size=group_size, block_k=bk, interpret=interpret,
            )
        return lambda: ops.decode_attention(
            q, k, v, lengths=lengths, block_k=bk, interpret=interpret
        )

    return make_run


# ---------------------------------------------------------------------------
# The tuner
# ---------------------------------------------------------------------------


class Autotuner:
    """Resolution + measurement + caching.  ``timer`` is injectable (tests
    pass a deterministic fake); ``cache`` defaults to the env-pointed JSON."""

    def __init__(
        self,
        cache: TuneCache | None = None,
        timer: Timer | None = None,
        *,
        top_k: int = TOP_K,
    ):
        self.cache = cache if cache is not None else TuneCache()
        self.timer = timer
        self.top_k = top_k
        self._memo: dict = {}

    # -- internals ----------------------------------------------------------

    def _timer(self) -> Timer:
        return self.timer if self.timer is not None else wall_timer()

    def _measure_seq(self, n: int, interpret: bool) -> int:
        cap = (
            MEASURE_SEQ_CAP_INTERPRET if interpret
            else MEASURE_SEQ_CAP_COMPILED
        )
        return max(128, min(seq_bucket(n), cap))

    def _resolve_measured(self, kernel, key, candidates, make_run_thunk) -> dict:
        """Cache lookup → sweep → persist.  Returns the cache entry.
        ``make_run_thunk()`` lazily builds the per-candidate runner factory so
        a cache hit never touches the backend."""
        entry = self.cache.get(key)
        if entry is not None:
            return entry
        # Sweeps ride the global recorder: the autotuner has no constructor
        # injection path, and --trace runs want tuned picks in the trace.
        rec = get_recorder()
        with rec.span("tune/measure", kernel=kernel,
                      n_candidates=len(candidates)):
            table = measure_candidates(
                make_run_thunk(), candidates, self._timer()
            )
        best = min(table, key=lambda c: table[c])
        rec.instant(
            "tune/pick", kernel=kernel,
            best=list(best) if isinstance(best, tuple) else int(best),
            seconds=table[best],
        )
        entry = {
            "kernel": kernel,
            "best": list(best) if isinstance(best, tuple) else int(best),
            "table": [
                {
                    "candidate": list(c) if isinstance(c, tuple) else int(c),
                    "seconds": s,
                }
                for c, s in sorted(table.items(), key=lambda kv: kv[1])
            ],
        }
        self.cache.put(key, entry)
        return entry

    def _pair_key_and_resolve(
        self, kernel, *, d, n, dtype, group_size, causal, interpret,
        make_run_for,
    ) -> tuple[int, int]:
        mode = tune_mode()
        memo_key = (
            mode, self.cache.path, kernel, d, seq_bucket(n), dtype,
            group_size, causal, interpret,
        )
        if memo_key in self._memo:
            return self._memo[memo_key]
        if mode == "off":
            nb = seq_bucket(n)
            pair = (min(DEFAULT_BLOCK, nb), min(DEFAULT_BLOCK, nb))
        elif mode == "analytic":
            pair = _analytic_pair(d, n=n, group_size=group_size)
        else:
            n_meas = self._measure_seq(n, interpret)
            cands = pair_candidates(
                d, n=n_meas, group_size=group_size, top_k=self.top_k
            )
            key = cache_key(
                kernel, backend=_backend_tag(interpret), dtype=dtype, d=d,
                group_size=group_size, n=n_meas, causal=causal,
            )
            entry = self._resolve_measured(
                kernel, key, cands, lambda: make_run_for(n_meas)
            )
            pair = tuple(entry["best"])
        self._memo[memo_key] = pair
        return pair

    # -- public resolution entry points -------------------------------------

    def resolve_pair(
        self,
        kernel: str,
        *,
        d: int,
        n: int,
        dtype: str = "float32",
        group_size: int = 1,
        causal: bool = False,
        interpret: bool | None = None,
    ) -> tuple[int, int]:
        """(block_q, block_k) for one forward/backward kernel key.  Kernels:
        flash_fwd | flash_dq | flash_dkv | xla_flash | distr_fwd | xla_distr.
        """
        if interpret is None:
            interpret = _default_interpret()

        def make_run_for(n_meas):
            if kernel == "flash_fwd":
                return _make_run_flash_fwd(n_meas, d, dtype, causal, interpret)
            if kernel == "xla_flash":
                return _make_run_xla_flash(n_meas, d, dtype, causal, interpret)
            if kernel in ("flash_dq", "flash_dkv"):
                return _make_run_flash_bwd(
                    n_meas, d, dtype, causal, interpret,
                    which=kernel.split("_")[1],
                )
            if kernel in ("distr_fwd", "xla_distr"):
                return _make_run_distr(
                    n_meas, d, dtype, causal, interpret, group_size,
                    xla=(kernel == "xla_distr"),
                )
            raise ValueError(f"unknown pair kernel {kernel!r}")

        return self._pair_key_and_resolve(
            kernel, d=d, n=n, dtype=dtype, group_size=group_size,
            causal=causal, interpret=interpret, make_run_for=make_run_for,
        )

    def resolve_distr_bwd(
        self,
        kernel: str,
        *,
        block_q: int,
        d: int,
        n: int,
        dtype: str = "float32",
        group_size: int = 2,
        causal: bool = False,
        interpret: bool | None = None,
        fwd_block_k: int | None = None,
    ) -> tuple[int, int]:
        """(block_q, block_k) for a distr *backward* kernel ("distr_dq" |
        "distr_dkv").  ``block_q`` is pinned by the caller — it is the LSH
        grouping granularity, shared with the forward and the saved
        permutations — and only ``block_k`` is resolved: the fwd pick (or
        128) outside measure mode, an independent sweep under it."""
        if kernel not in ("distr_dq", "distr_dkv"):
            raise ValueError(f"unknown distr bwd kernel {kernel!r}")
        if interpret is None:
            interpret = _default_interpret()
        mode = tune_mode()
        memo_key = (
            mode, self.cache.path, kernel, block_q, d, seq_bucket(n), dtype,
            group_size, causal, interpret, fwd_block_k,
        )
        if memo_key in self._memo:
            pair = self._memo[memo_key]
        elif mode != "measure":
            bk = (
                fwd_block_k if fwd_block_k is not None
                else min(DEFAULT_BLOCK, seq_bucket(n))
            )
            pair = (block_q, bk)
        else:
            n_meas = self._measure_seq(n, interpret)
            bq = min(block_q, n_meas)
            cands = distr_bwd_candidates(
                d, block_q=bq, n=n_meas, group_size=group_size,
                top_k=self.top_k,
            )
            # The grouping pin: the backward sweep varies block_k ONLY —
            # a refactor that starts sweeping (l, m) pairs here would
            # silently change which columns the saved permutations group.
            # Fail loudly on the candidate space and on the cache entry (a
            # pair-shaped `best` means a drifted writer poisoned the key).
            assert all(not isinstance(c, (tuple, list)) for c in cands), (
                "distr backward candidates must be block_k scalars; "
                "block_q is the LSH grouping granularity and stays pinned"
            )
            key = cache_key(
                f"{kernel}@l={block_q}", backend=_backend_tag(interpret),
                dtype=dtype, d=d, group_size=group_size, n=n_meas,
                causal=causal,
            )
            entry = self._resolve_measured(
                kernel, key, cands,
                lambda: _make_run_distr_bwd(
                    n_meas, d, dtype, causal, interpret, group_size, bq,
                    which=kernel.split("_")[1],
                ),
            )
            assert not isinstance(entry["best"], (tuple, list)), (
                f"distr backward cache entry for {key!r} holds a (l, m) "
                "pair — block_q must stay pinned to the LSH grouping "
                "granularity, only block_k is tuned"
            )
            pair = (block_q, int(entry["best"]))
        self._memo[memo_key] = pair
        return pair

    def resolve_decode(
        self,
        *,
        d: int,
        n: int,
        dtype: str = "bfloat16",
        group_size: int = 1,
        interpret: bool | None = None,
    ) -> int:
        """Split-K ``block_k`` for the decode kernel at cache capacity n."""
        if interpret is None:
            interpret = _default_interpret()
        mode = tune_mode()
        memo_key = (
            mode, self.cache.path, "decode", d, seq_bucket(n), dtype,
            group_size, interpret,
        )
        if memo_key in self._memo:
            return self._memo[memo_key]
        if mode == "off":
            bk = min(DEFAULT_BLOCK, seq_bucket(n))
        elif mode == "analytic":
            bk = _analytic_decode(n)
        else:
            n_meas = self._measure_seq(n, interpret)
            cands = decode_candidates(n_meas)
            key = cache_key(
                "decode", backend=_backend_tag(interpret), dtype=dtype, d=d,
                group_size=group_size, n=n_meas, causal=False,
            )
            entry = self._resolve_measured(
                "decode", key, cands,
                lambda: _make_run_decode(n_meas, d, dtype, interpret, group_size),
            )
            bk = int(entry["best"])
        self._memo[memo_key] = bk
        return bk

    def resolve_paged_decode(
        self,
        *,
        d: int,
        n: int,
        dtype: str = "bfloat16",
        group_size: int = 1,
        interpret: bool | None = None,
    ) -> int:
        """Pool block size for the *paged* decode kernel at per-request
        capacity ``n`` (kernels/paged_decode.py).  Unlike the contiguous
        split-K knob this is also the allocator granularity — the
        PagedServeEngine resolves it once at construction (its pools are
        shaped by it), which doubles as the warm-up: measure-mode sweeps
        run here, never inside a serving tick."""
        if interpret is None:
            interpret = _default_interpret()
        mode = tune_mode()
        memo_key = (
            mode, self.cache.path, "paged_decode", d, seq_bucket(n), dtype,
            group_size, interpret,
        )
        if memo_key in self._memo:
            return self._memo[memo_key]
        if mode == "off":
            bs = min(DEFAULT_BLOCK, seq_bucket(n))
        elif mode == "analytic":
            bs = _analytic_decode(n)
        else:
            n_meas = self._measure_seq(n, interpret)
            cands = paged_block_candidates(n_meas)
            key = cache_key(
                "paged_decode", backend=_backend_tag(interpret), dtype=dtype,
                d=d, group_size=group_size, n=n_meas, causal=False,
            )
            entry = self._resolve_measured(
                "paged_decode", key, cands,
                lambda: _make_run_paged_decode(
                    n_meas, d, dtype, interpret, group_size
                ),
            )
            bs = int(entry["best"])
        self._memo[memo_key] = bs
        return bs

    def resolve(
        self,
        kind: str,
        *,
        d: int,
        n: int,
        dtype: str = "float32",
        group_size: int = 1,
        causal: bool = False,
        interpret: bool | None = None,
        bwd: bool = False,
    ) -> BlockSizes:
        """Full BlockSizes record for an attention implementation kind:
        "flash" (Pallas), "xla_flash", "distr" (Pallas; block_q doubles as
        the LSH granularity so the bwd kernels keep the fwd pair), or
        "xla_distr".  For "flash", ``bwd=True`` eagerly resolves the
        backward dQ/dKV keys too (measure mode; training warm-up) — the
        default leaves them None, and ``ops._flash_vjp_bwd`` resolves them
        lazily when grad tracing first reaches the op, so forward-only
        dispatch never pays a backward sweep."""
        if kind == "flash":
            fwd = self.resolve_pair(
                "flash_fwd", d=d, n=n, dtype=dtype, causal=causal,
                interpret=interpret,
            )
            bs = BlockSizes.from_pair(*fwd)
            if bwd and tune_mode() == "measure":
                dq = self.resolve_pair(
                    "flash_dq", d=d, n=n, dtype=dtype, causal=causal,
                    interpret=interpret,
                )
                dkv = self.resolve_pair(
                    "flash_dkv", d=d, n=n, dtype=dtype, causal=causal,
                    interpret=interpret,
                )
                bs = bs.with_(
                    block_q_dq=dq[0], block_k_dq=dq[1],
                    block_q_dkv=dkv[0], block_k_dkv=dkv[1],
                )
            return bs
        if kind in ("xla_flash", "distr", "xla_distr"):
            kernel = {
                "xla_flash": "xla_flash",
                "distr": "distr_fwd",
                "xla_distr": "xla_distr",
            }[kind]
            fwd = self.resolve_pair(
                kernel, d=d, n=n, dtype=dtype, group_size=group_size,
                causal=causal, interpret=interpret,
            )
            return BlockSizes.from_pair(*fwd)
        raise ValueError(f"unknown resolution kind {kind!r}")


# ---------------------------------------------------------------------------
# Module-level singleton + convenience wrappers (the dispatch entry points)
# ---------------------------------------------------------------------------

_AUTOTUNER: Autotuner | None = None


def get_autotuner() -> Autotuner:
    global _AUTOTUNER
    if _AUTOTUNER is None:
        _AUTOTUNER = Autotuner()
    return _AUTOTUNER


def reset_autotuner(tuner: Autotuner | None = None) -> None:
    """Swap/clear the process-wide tuner (tests: inject fake timers/caches)."""
    global _AUTOTUNER
    _AUTOTUNER = tuner


def resolve_block_sizes(kind: str, **kw) -> BlockSizes:
    return get_autotuner().resolve(kind, **kw)


def resolve_decode_block(**kw) -> int:
    return get_autotuner().resolve_decode(**kw)


def resolve_paged_decode_block(**kw) -> int:
    return get_autotuner().resolve_paged_decode(**kw)


def warm_paged_engine(cfg, max_len: int, *, decode: bool = True,
                      mesh_prefill_buckets: bool = False,
                      buckets=(32, 64, 128, 256, 512, 1024,
                               2048, 4096)) -> dict:
    """Pre-resolve the block-size keys a PagedServeEngine will hit: the
    paged-decode pool block (which shapes the pools themselves, so it MUST
    resolve before construction).  Measure-mode sweeps run here, once —
    mirroring :func:`warm_engine` for the slot engine.  Returns
    {site: resolved} for logging.

    ``mesh_prefill_buckets`` additionally resolves the whole-prompt
    ring-prefill attend at each bucket ≤ max_len (the mesh engine's
    ``prefill_mesh_run`` buckets).  Call it with the engine's mesh ACTIVE
    (``maybe_set_mesh``): ``api.resolve_attention_blocks`` then keys each
    bucket by its per-ring-shard sequence length, so the tuned tile sizes
    match what each device actually runs — the same per-shard keying the
    slot engine's long-prompt path gets from :func:`warm_engine`."""
    out: dict = {}
    if cfg.attention.impl == "reference":
        return out
    g = (
        cfg.attention.distr.group_size if cfg.attention.distr_decode else 1
    )
    if decode:
        # Keyed by the KV-pool dtype (bf16, the serve default), like the
        # contiguous decode key.
        out["paged_decode"] = get_autotuner().resolve_paged_decode(
            d=cfg.head_dim_, n=max_len, dtype="bfloat16", group_size=g
        )
    if mesh_prefill_buckets:
        from repro.core import api

        dtype = (
            "bfloat16" if getattr(cfg, "compute_dtype", "") == "bfloat16"
            else "float32"
        )
        live = sorted({min(b, max_len) for b in buckets if b <= max_len}
                      | {max_len})
        for b in live:
            out[f"mesh_prefill/{b}"] = api.resolve_attention_blocks(
                cfg.attention, d=cfg.head_dim_, n_q=b, n_k=b, dtype=dtype,
                causal=True,
            )
    return out


def warm_engine(cfg, max_len: int, *, buckets=(32, 64, 128, 256, 512, 1024,
                                              2048, 4096)) -> dict:
    """Pre-resolve every block-size key a ServeEngine will hit: the prefill
    attend at each bucket ≤ max_len and the decode split-K block at the
    cache capacity.  In ``measure`` mode this runs (and persists) the sweeps
    up front so no serving step ever blocks on a timing run; in ``off`` /
    ``analytic`` it is effectively free.  Forward keys only: the backward
    dQ/dKV keys resolve lazily at backward-trace time, which a serving
    process never reaches.  Returns {site: resolved} for logging."""
    from repro.core import api

    acfg = cfg.attention
    out: dict = {}
    dtype = (
        "bfloat16" if getattr(cfg, "compute_dtype", "") == "bfloat16"
        else "float32"
    )
    d = cfg.head_dim_
    if acfg.impl != "reference":
        live = sorted({min(b, max_len) for b in buckets if b <= max_len}
                      | {max_len})
        for b in live:
            out[f"prefill/{b}"] = api.resolve_attention_blocks(
                acfg, d=d, n_q=b, n_k=b, dtype=dtype, causal=True
            )
        g = acfg.distr.group_size if acfg.distr_decode else 1
        # The decode key is keyed by the KV-cache dtype (bf16 — the
        # serve.kv_cache.init_cache default the engine uses), not the
        # compute dtype: decode_attention resolves from the cache arrays.
        bk = get_autotuner().resolve_decode(
            d=d, n=max_len, dtype="bfloat16", group_size=g
        )
        out["decode"] = BlockSizes(
            block_k_decode=bk, num_splits=-(-max_len // bk)
        )
    return out
