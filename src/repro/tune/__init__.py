"""Empirical block-size autotuning (paper §3.3.1, taken to hardware).

The analytic model in ``core.block_size`` ranks candidate (l, m) tiles by
the paper's HBM-I/O objective; this package closes the loop by *measuring*
the top candidates on the actual backend and caching the winner, keyed by
``(kernel, backend, dtype, d, G*, seq-bucket, causal)``.

Env knobs (DESIGN.md §Autotuning):

  REPRO_TUNE=off|analytic|measure   resolution mode for "auto" (None) block
                                    sizes; default "off" = static 128×128.
  REPRO_TUNE_CACHE=<path>           persistent JSON cache location.
"""
from repro.tune.block_sizes import BlockSizes
from repro.tune.cache import TuneCache, cache_key, default_cache_path, seq_bucket
from repro.tune.measure import measure_candidates, wall_timer
from repro.tune.autotune import (
    Autotuner,
    decode_candidates,
    get_autotuner,
    pair_candidates,
    reset_autotuner,
    resolve_block_sizes,
    resolve_decode_block,
    resolve_paged_decode_block,
    tune_mode,
    warm_engine,
    warm_paged_engine,
)

__all__ = [
    "Autotuner",
    "BlockSizes",
    "TuneCache",
    "cache_key",
    "decode_candidates",
    "default_cache_path",
    "get_autotuner",
    "measure_candidates",
    "pair_candidates",
    "reset_autotuner",
    "resolve_block_sizes",
    "resolve_decode_block",
    "resolve_paged_decode_block",
    "seq_bucket",
    "tune_mode",
    "wall_timer",
    "warm_engine",
    "warm_paged_engine",
]
