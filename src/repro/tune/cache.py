"""Persistent JSON cache for measured block sizes.

Keyed by ``(kernel, backend, dtype, d, G*, seq-bucket, causal)`` — the
parameters the optimum actually shifts with (FlashAttention's IO model: the
right tile depends on head dim, element width, the grouped score width
d/G*, and the memory system).  Batch and head counts only scale the grid,
not the per-instance working set, so they are deliberately *not* part of
the key — one warm-up covers every batch size.

The file is a flat ``{key: entry}`` JSON object; entries store the winning
blocks plus the measured table so benchmarks can re-plot without re-timing.
``REPRO_TUNE_CACHE`` overrides the location (serve/train jobs point it at a
shared path, warm once, and every later process resolves by lookup).
"""
from __future__ import annotations

import json
import os
import tempfile


def default_cache_path() -> str:
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "blocksizes.json"
    )


def dtype_str(x) -> str:
    """Canonical dtype label for cache keys ("bfloat16" | "float32").
    Accepts an array or a dtype; anything non-bf16 keys as float32 (the
    kernels accumulate in f32 either way)."""
    dt = getattr(x, "dtype", x)
    return "bfloat16" if str(dt) == "bfloat16" else "float32"


def seq_bucket(n: int) -> int:
    """Power-of-two sequence bucket (floor 128): nearby lengths share a
    tuning entry, mirroring the serve engine's prefill buckets."""
    b = 128
    while b < n:
        b *= 2
    return b


def cache_key(
    kernel: str,
    *,
    backend: str,
    dtype: str,
    d: int,
    group_size: int = 1,
    n: int,
    causal: bool = False,
) -> str:
    return (
        f"{kernel}|backend={backend}|dtype={dtype}|d={int(d)}"
        f"|g={int(group_size)}|nb={seq_bucket(int(n))}|causal={bool(causal)}"
    )


class TuneCache:
    """In-memory view of one JSON cache file (lazy load, atomic save)."""

    def __init__(self, path: str | None = None):
        self._explicit_path = path
        self._data: dict | None = None
        self._loaded_from: str | None = None

    @property
    def path(self) -> str:
        return self._explicit_path or default_cache_path()

    def _load(self) -> dict:
        path = self.path
        if self._data is None or self._loaded_from != path:
            self._loaded_from = path
            self._data = {}
            try:
                with open(path, encoding="utf-8") as f:
                    self._data = json.load(f)
            except (json.JSONDecodeError, UnicodeDecodeError):
                # Corrupt cache (e.g. a pre-atomic-save writer died
                # mid-write, or torn non-UTF-8 bytes): quarantine instead
                # of crashing the caller — engine construction warms
                # through here, and save() must start from a clean slate.
                self._quarantine(path)
            except (OSError, ValueError):
                pass
        return self._data

    @staticmethod
    def _quarantine(path: str) -> None:
        """Move an unparseable cache aside (``path + '.corrupt'``): the bad
        bytes stay inspectable, later saves start from a clean slate, and no
        future load (or merge-on-save) re-parses garbage.  Never raises."""
        try:
            os.replace(path, path + ".corrupt")
        except OSError:
            pass

    def get(self, key: str) -> dict | None:
        return self._load().get(key)

    def put(self, key: str, entry: dict, *, save: bool = True) -> None:
        data = self._load()
        data[key] = entry
        if save:
            self.save()

    def save(self) -> None:
        path = self.path
        data = self._load()
        # Merge-on-save: the path may be shared across processes (the
        # documented warm-once pattern), so re-read and fold in entries a
        # concurrent writer persisted since our load — our own keys win.
        try:
            with open(path, encoding="utf-8") as f:
                on_disk = json.load(f)
        except (OSError, ValueError):
            # ValueError covers JSONDecodeError and (torn non-UTF-8 bytes)
            # UnicodeDecodeError: a corrupt concurrent write merges as
            # empty and the atomic replace below overwrites it wholesale.
            on_disk = {}
        data = {**on_disk, **data}
        self._data = data
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        # Atomic replace: a crashed/parallel writer never leaves a torn file.
        fd, tmp = tempfile.mkstemp(
            dir=os.path.dirname(path) or ".", suffix=".tmp"
        )
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(data, f, indent=1, sort_keys=True)
                # Durability, not just name-atomicity: without the fsync a
                # crash shortly after os.replace can still surface a
                # zero-length/partial file on some filesystems.
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def clear_memory(self) -> None:
        """Drop the in-memory view (tests; a changed env path reloads too)."""
        self._data = None
        self._loaded_from = None
