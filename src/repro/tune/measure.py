"""Measurement harness: time candidate block sizes with an injectable timer.

``wall_timer`` is the real thing (warmup + ``block_until_ready`` medians);
tests inject a deterministic fake ``timer(fn, candidate) -> seconds`` so
tuning decisions are reproducible without wall-clock noise.
"""
from __future__ import annotations

import time
from typing import Callable

import jax

# timer(run_fn, candidate) -> seconds; run_fn is a zero-arg callable that
# executes one candidate configuration end to end.
Timer = Callable[[Callable[[], object], object], float]


def wall_timer(*, warmup: int = 1, iters: int = 3) -> Timer:
    """Median wall-clock timer over jitted callables (device-synchronised)."""

    def timer(run_fn: Callable[[], object], candidate: object) -> float:
        del candidate
        for _ in range(warmup):
            jax.block_until_ready(run_fn())
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(run_fn())
            times.append(time.perf_counter() - t0)
        times.sort()
        return times[len(times) // 2]

    return timer


def measure_candidates(
    make_run, candidates: list, timer: Timer
) -> dict:
    """Time every candidate; returns ``{candidate: seconds}``.

    ``make_run(candidate)`` builds the zero-arg callable for one candidate
    (inputs are closed over, so every candidate sees identical data).
    Candidates that fail to build or run (e.g. a tile the backend rejects)
    are skipped rather than aborting the sweep.
    """
    results: dict = {}
    for cand in candidates:
        try:
            run_fn = make_run(cand)
            results[cand] = float(timer(run_fn, cand))
        except Exception:  # noqa: BLE001 — an illegal tile is not fatal
            continue
    if not results:
        raise RuntimeError(f"no candidate in {candidates!r} was measurable")
    return results
